"""vecsim — jax-vectorized multi-deployment sweep engine.

Evaluates thousands of independent AllConcur+/AllConcur/AllGather
deployments in one jax program via a batched min-plus round recurrence,
cross-validated (exactly, not just within tolerance) against the
discrete-event simulator in :mod:`repro.sim`.  See README.md in this
directory for the recurrence derivation and when to trust which engine.
"""
from .clients import (ClientLatencies, arrival_times, client_latencies,
                      closed_loop_latencies, draw_keys, keys_from_uniform,
                      mc_client_latencies, server_streams, smr_round_times,
                      zipf_cdf)
from .engine import RoundTimes, run_reliable, run_unreliable, summarize
from .failures import (MonteCarloResult, MonteCarloTimes, monte_carlo,
                       monte_carlo_times)
from .sweep import SweepConfig, SweepResult, grid, sweep
from .topology import (ReliableTables, UnreliableTables, message_bytes,
                       reliable_tables, smr_message_bytes, unreliable_tables)

__all__ = [
    "RoundTimes", "run_reliable", "run_unreliable", "summarize",
    "ClientLatencies", "arrival_times", "client_latencies",
    "closed_loop_latencies", "draw_keys", "keys_from_uniform",
    "mc_client_latencies", "server_streams", "smr_round_times", "zipf_cdf",
    "MonteCarloResult", "MonteCarloTimes", "monte_carlo",
    "monte_carlo_times",
    "SweepConfig", "SweepResult", "grid", "sweep",
    "ReliableTables", "UnreliableTables", "message_bytes",
    "reliable_tables", "smr_message_bytes", "unreliable_tables",
]
