"""Tensorized SMR client layer: arrivals -> batches -> acks, in one jit.

``benchmarks/smr_throughput.py`` replays clients one heap event at a time;
this module lifts the *client* dimension into jax so a million simulated
clients against thousands of (config x crash-schedule) deployments reduce to
a few array programs over the per-server round timelines that
:mod:`repro.vecsim.engine` (failure-free) and
:func:`repro.vecsim.failures.monte_carlo_times` (crash/eon-flip splices)
already produce.

The model (cross-validated at **zero tolerance** against
``build_smr_simulation`` in ``tests/test_vecsim_clients.py``):

- Clients are co-located round-robin: client ``cid`` submits to server
  ``cid % n`` and only that home server acks it
  (``SMRService._ack`` semantics).
- Each server serves its clients FIFO (``SMRService.pending`` order =
  submit-time order) in batches of at most ``batch_max`` per A-broadcast
  round.
- **Batch formation** is a segment-reduce + tiny scan.  With round ``r``
  (1-based) entered at ``E[r-1]`` and completed at ``C[r-1]``, let
  ``S_r = #{j : s_j <= E[r-1]}`` be the arrivals by the abcast of round
  ``r`` (the :mod:`repro.kernels.clients_segred` kernel).  The number of
  requests *served through* round ``r`` follows

      cum_r = min(S_r, cum_{r-delta} + batch_max),    cum_{<=0} = 0

  with ``delta = 2`` for DUAL (a request's payload rides two consecutive
  rounds — fresh in round ``a``, duplicate in ``a+1`` — so capacity taken
  in round ``a`` frees at ``a+2``) and ``delta = 1`` otherwise.  Request
  ``j`` (0-based FIFO rank) is then abcast in round
  ``a(j) = searchsorted(cum, j+1, side="left") + 1`` and acked at

      C[a(j)]      (DUAL: A-delivery lags one round)
      C[a(j) - 1]  (RELIABLE_ONLY / UNRELIABLE_ONLY)

  This recurrence is exact including overflow backlogs and partially-filled
  DUAL batches (new requests joining a duplicate round's spare capacity).
- **Closed-loop lockstep**: with ``cps <= batch_max`` clients per server all
  resubmitting on ack, generation ``g`` of every client on server ``h`` is
  abcast in lockstep; latency is ``C[g,h] - E[g,h]`` (non-dual) or
  ``C[2g+1,h] - E[2g,h]`` (DUAL) with no per-request state at all.

Exactness contract: given a round timeline, ack times equal the event
simulator's **bit-for-bit** (the ack is a gather of the same float, the
latency the same two floats subtracted).  End-to-end against
:mod:`repro.vecsim.engine` timelines the agreement is the engine's own
cross-validation tolerance (~1e-12 relative; float association in the
NIC scan), with SMR-sized cost tables from
:func:`repro.vecsim.topology.smr_message_bytes`.  Monte-Carlo timelines
are spliced *models* (see ``failures.py``) — the client mapping on top of
them is exact, the timeline itself is the approximation.

Percentiles use the repo-wide nearest-rank rule
(:mod:`repro.smr.percentiles`): ``idx = min(int(p * count), count - 1)``
over the ascending sort, replicated here as a gather so the jit path is
bit-for-bit equal to the Python helper.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from ..smr.workload import ZipfianGenerator
from .engine import RoundTimes, run_reliable, run_unreliable
from .topology import reliable_tables, smr_message_bytes, unreliable_tables

MODES = ("allconcur+", "allconcur", "allgather")
PCTS = (0.50, 0.99, 0.999)


def _jax():
    import jax
    import jax.numpy as jnp
    return jax, jnp


def _delta(mode: str) -> int:
    """Rounds a request's payload occupies batch capacity (see module doc)."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    return 2 if mode == "allconcur+" else 1


# --------------------------------------------------------------------------
# key popularity (vectorized mirror of smr.workload)

def zipf_cdf(nkeys: int, theta: float = 0.99) -> np.ndarray:
    """The event generator's zipfian CDF, verbatim (same accumulation order,
    so both engines bisect the identical float array)."""
    return np.asarray(ZipfianGenerator(nkeys, theta)._cdf, dtype=np.float64)


def keys_from_uniform(u, cdf):
    """Map uniform draws ``u in [0, 1)`` to zipfian keys: the vectorized
    twin of ``ZipfianGenerator.draw`` — ``bisect_left`` == ``searchsorted
    side="left"`` — including the clamp to ``nkeys - 1`` for draws above a
    CDF whose float accumulation fell short of 1.0."""
    _, jnp = _jax()
    cdf = jnp.asarray(cdf)
    idx = jnp.searchsorted(cdf, jnp.asarray(u), side="left")
    return jnp.minimum(idx, cdf.shape[0] - 1).astype(jnp.int32)


def draw_keys(key, shape, *, distribution: str = "zipfian", nkeys: int = 256,
              theta: float = 0.99):
    """Seeded key stream of the given shape (int32 in ``[0, nkeys)``)."""
    jax, jnp = _jax()
    if distribution == "uniform":
        return jax.random.randint(key, shape, 0, nkeys, dtype=jnp.int32)
    if distribution != "zipfian":
        raise ValueError(f"distribution must be 'zipfian' or 'uniform', "
                         f"got {distribution!r}")
    u = jax.random.uniform(key, shape)
    return keys_from_uniform(u, zipf_cdf(nkeys, theta))


# --------------------------------------------------------------------------
# arrival streams

def arrival_times(seed: int, num_clients: int, requests_per_client: int,
                  rate: float) -> np.ndarray:
    """Open-loop submit times, ``[num_clients, requests_per_client]`` f64.

    Each client is an independent Poisson process of ``rate`` req/s, seeded
    by ``fold_in(PRNGKey(seed), cid)`` — per-client counters, so the stream
    of client ``cid`` is invariant to the population size and to whether the
    draw runs plain, jitted or vmapped.
    """
    if rate <= 0:
        raise ValueError(f"open-loop arrival requires rate > 0, got {rate!r}")
    jax, jnp = _jax()
    from jax.experimental import enable_x64
    with enable_x64():
        base = jax.random.PRNGKey(seed)

        def one(cid):
            k = jax.random.fold_in(base, cid)
            gaps = jax.random.exponential(
                k, (requests_per_client,), dtype=jnp.float64) / rate
            return jnp.cumsum(gaps)

        return np.asarray(jax.jit(jax.vmap(one))(jnp.arange(num_clients)))


def server_streams(arrivals, n: int) -> np.ndarray:
    """Group per-client arrivals into per-home-server FIFO streams.

    ``arrivals``: ``[num_clients, q]`` with client ``cid`` homed on
    ``cid % n`` (the event harness's ``assign_round_robin``).  Returns
    ``[n, (num_clients // n) * q]`` submit times, ascending per server.
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    c, q = arrivals.shape
    if c % n:
        raise ValueError(f"num_clients={c} must be a multiple of n={n}")
    # cid = i * n + h  ->  [cps, n, q] -> per-server flat stream
    s = arrivals.reshape(c // n, n, q).transpose(1, 0, 2).reshape(n, -1)
    return np.sort(s, axis=1)


# --------------------------------------------------------------------------
# the jitted pipeline

def _counts_fn(engine: str):
    if engine == "pallas":
        from ..kernels.clients_segred import segment_counts
        return segment_counts
    if engine == "vec":
        from ..kernels.clients_segred import segment_counts_reference
        return segment_counts_reference
    raise ValueError(f"engine must be 'vec' or 'pallas', got {engine!r}")


def _make_cum_scan(jax, jnp, delta: int, batch_max: int):
    def cum_scan(counts):
        # cum_r = min(S_r, cum_{r-delta} + batch_max); carry the last delta
        def step(carry, s_r):
            cur = jnp.minimum(s_r, carry[-1] + batch_max)
            return (cur,) + carry[:-1], cur

        init = (jnp.zeros(counts.shape[0], counts.dtype),) * delta
        _, cum = jax.lax.scan(step, init, counts.T)
        return cum.T                                    # [n, K]

    return cum_scan


def _pct_gather(jnp, lat_inf_flat, total, ps):
    """Pooled nearest-rank over a flat +inf-masked latency vector — the jnp
    twin of repro.smr.percentiles.nearest_rank (same double product, same
    truncation, same clamp), so jit and Python report identical floats."""
    x = jnp.sort(lat_inf_flat)
    out = []
    for p in ps:
        idx = jnp.minimum((p * total).astype(jnp.int32), total - 1)
        v = x[jnp.maximum(idx, 0)]
        out.append(jnp.where(total > 0, v, jnp.nan))
    return jnp.stack(out)


@functools.lru_cache(maxsize=None)
def _compiled_pipeline(delta: int, ack_lag: int, batch_max: int,
                       engine: str, ps: Tuple[float, ...]):
    """One jit: segment-reduce -> capacity scan -> round assignment -> ack
    gather -> pooled nearest-rank percentiles.  Static in everything but the
    timeline/stream arrays; jax re-specializes per shape as usual."""
    jax, jnp = _jax()
    _counts = _counts_fn(engine)
    cum_scan = _make_cum_scan(jax, jnp, delta, batch_max)

    def pipeline(entry, ack_times, s):
        # entry/ack_times: [n, K] per-server round timelines; s: [n, M]
        k = entry.shape[1]
        m = s.shape[1]
        counts = _counts(s, entry)                      # [n, K] int32
        cum = cum_scan(counts)
        ranks = jnp.arange(1, m + 1, dtype=cum.dtype)
        a0 = jax.vmap(
            lambda c: jnp.searchsorted(c, ranks, side="left"))(cum)
        ack_idx = a0 + ack_lag
        valid = (ack_idx < k) & jnp.isfinite(s)
        ack = jnp.take_along_axis(ack_times, jnp.clip(ack_idx, 0, k - 1),
                                  axis=1)
        lat = ack - s
        cnt = jnp.sum(valid)
        pct = _pct_gather(jnp, jnp.where(valid, lat, jnp.inf).ravel(),
                          cnt, ps)
        return a0, ack, lat, valid, pct, cnt

    return jax.jit(pipeline)


@functools.lru_cache(maxsize=None)
def _compiled_mc_pipeline(delta: int, batch_max: int, engine: str,
                          ps: Tuple[float, ...]):
    """The schedule-pooled variant: map the assignment pipeline over [S, R]
    spliced timelines (shared by all servers), keep only the masked latency
    pool and pooled percentiles so XLA drops per-request intermediates."""
    jax, jnp = _jax()
    _counts = _counts_fn(engine)
    cum_scan = _make_cum_scan(jax, jnp, delta, batch_max)

    def pipeline(entry, deliver, s):
        # entry/deliver: [S, R]; s: [n, M]
        n, m = s.shape
        r = entry.shape[1]
        ranks = jnp.arange(1, m + 1, dtype=jnp.int32)

        def one(rows):
            e_row, d_row = rows
            e = jnp.broadcast_to(e_row, (n, r))
            counts = _counts(s, e)
            cum = cum_scan(counts)
            a0 = jax.vmap(
                lambda c: jnp.searchsorted(c, ranks, side="left"))(cum)
            # the MC splice folds the A-delivery lag into `deliver`
            valid = (a0 < r) & jnp.isfinite(s)
            ack = d_row[jnp.clip(a0, 0, r - 1)]
            return jnp.where(valid, ack - s, jnp.inf), jnp.sum(valid)

        lat, cnts = jax.lax.map(one, (entry, deliver))
        total = jnp.sum(cnts)
        return _pct_gather(jnp, lat.ravel(), total, ps), total

    return jax.jit(pipeline)


@dataclass(frozen=True)
class ClientLatencies:
    """Per-request results of one deployment (or one spliced schedule)."""
    round_idx: np.ndarray    # [n, M] 0-based abcast round (K = unserved)
    ack: np.ndarray          # [n, M] ack times (garbage where ~valid)
    latency: np.ndarray      # [n, M] ack - submit
    valid: np.ndarray        # [n, M] served within the timeline horizon
    percentiles: dict        # {p: seconds} pooled nearest-rank
    served: int              # valid request count


def client_latencies(entry, ack_times, submits, *, mode: str,
                     batch_max: int, ack_lag: Optional[int] = None,
                     engine: str = "vec",
                     ps: Sequence[float] = PCTS) -> ClientLatencies:
    """Open-loop client latencies against one per-server round timeline.

    ``entry[h, k]`` / ``ack_times[h, k]``: entry and *ack source* time of
    (1-based) round ``k+1`` on server ``h``.  For engine timelines pass
    ``entry = times.start.T`` and ``ack_times = times.completion.T``; the
    DUAL one-round delivery lag is applied here (``ack_lag = 1``).  For
    Monte-Carlo timelines pass ``failures.MonteCarloTimes.entry/deliver``
    (broadcast per server) with ``ack_lag = 0`` — the splice already folds
    the lag into ``deliver``.

    ``submits[h, j]``: ascending per-server FIFO submit times
    (:func:`server_streams`); ``+inf`` marks ragged padding.
    """
    from jax.experimental import enable_x64
    lag = (1 if mode == "allconcur+" else 0) if ack_lag is None else ack_lag
    if batch_max < 1:
        raise ValueError(f"batch_max must be >= 1, got {batch_max}")
    fn = _compiled_pipeline(_delta(mode), lag, int(batch_max), engine,
                            tuple(float(p) for p in ps))
    with enable_x64():
        a0, ack, lat, valid, pct, cnt = fn(
            np.asarray(entry, np.float64), np.asarray(ack_times, np.float64),
            np.asarray(submits, np.float64))
    pct = np.asarray(pct)
    return ClientLatencies(
        round_idx=np.asarray(a0), ack=np.asarray(ack),
        latency=np.asarray(lat), valid=np.asarray(valid),
        percentiles={p: float(pct[i]) for i, p in enumerate(ps)},
        served=int(cnt))


def mc_client_latencies(mc_entry, mc_deliver, submits, *, mode: str,
                        batch_max: int, engine: str = "vec",
                        ps: Sequence[float] = PCTS) -> dict:
    """Client percentiles pooled across Monte-Carlo schedules.

    ``mc_entry`` / ``mc_deliver``: ``[S, R]`` spliced timelines
    (:func:`repro.vecsim.failures.monte_carlo_times`) shared by all ``n``
    servers of the symmetric deployment; ``submits``: ``[n, M]`` per-server
    streams replayed against every schedule.  Returns pooled nearest-rank
    percentiles plus the served-request count.
    """
    from jax.experimental import enable_x64
    fn = _compiled_mc_pipeline(_delta(mode), int(batch_max), engine,
                               tuple(float(p) for p in ps))
    with enable_x64():
        pct, total = fn(np.asarray(mc_entry, np.float64),
                        np.asarray(mc_deliver, np.float64),
                        np.asarray(submits, np.float64))
    pct = np.asarray(pct)
    return {"percentiles": {p: float(pct[i]) for i, p in enumerate(ps)},
            "served": int(total),
            "schedules": int(np.asarray(mc_entry).shape[0])}


# --------------------------------------------------------------------------
# closed-loop lockstep (no per-request state at all)

def closed_loop_latencies(times: RoundTimes, *, mode: str, batch_max: int,
                          clients_per_server: int) -> np.ndarray:
    """Latency per (generation, server) under closed-loop lockstep.

    With ``clients_per_server <= batch_max`` clients all submitting at t=0
    and resubmitting on ack, every server's batches stay in lockstep:
    generation ``g`` is abcast as one full batch in round ``g+1`` (non-dual)
    or round ``2g+1`` (DUAL, where odd rounds carry only duplicates).
    Returns ``[..., G, n]``; each entry is the identical latency of all
    ``clients_per_server`` clients of that server (uniform weights, so
    pooled nearest-rank percentiles over this array equal the per-request
    ones).
    """
    if clients_per_server > batch_max:
        raise ValueError(
            f"lockstep requires clients_per_server <= batch_max, got "
            f"{clients_per_server} > {batch_max} (use the open-loop path)")
    _delta(mode)  # validates mode
    c = np.asarray(times.completion)
    e = np.asarray(times.start)
    k = c.shape[-2]
    if mode == "allconcur+":
        g = k // 2   # gen g: abcast at E[2g], acked at C[2g+1]
        return c[..., 1::2, :][..., :g, :] - e[..., ::2, :][..., :g, :]
    return c - e


# --------------------------------------------------------------------------
# SMR-sized engine timelines

def smr_round_times(mode: str, n: int, *, reqs_per_round: int, rounds: int,
                    network: str = "sdc", value_size: int = 16,
                    batch_cap: Optional[int] = None,
                    engine: str = "vec") -> RoundTimes:
    """Failure-free round timeline with SMR-sized messages.

    Cost tables are built with ``nbytes = smr_message_bytes(mode,
    reqs_per_round)`` — the constant representative frame carrying
    ``reqs_per_round`` put requests — so the vectorized timeline charges the
    same wire bytes the event simulator's SMR payloads serialize to (exact
    within the small-varint band; see :func:`smr_message_bytes`).
    ``engine`` is forwarded to the round engine ("vec" | "pallas").
    """
    nbytes = smr_message_bytes(mode, reqs_per_round, value_size=value_size)
    if mode == "allconcur":
        t = reliable_tables(n, network=network, mode=mode, nbytes=nbytes)
        return run_reliable(t.adj, t.edge_off, t.occ, t.prop, rounds=rounds,
                            engine=engine)
    t = unreliable_tables(n, network=network, mode=mode, nbytes=nbytes)
    return run_unreliable(t.parent, t.send_off, t.occ, t.prop, rounds=rounds,
                          engine=engine)
