"""Batched min-plus round recurrence for failure-free AllConcur+/AllGather.

A failure-free round is a *deterministic* function of the overlay digraph and
the network model: message ``s`` reaches server ``v`` along overlay edges, and
the arrival time is a tropical (min-plus) path sum

    T[s, v] = min_u ( cost[s, u, v] + T[s, u] )

iterated to fixpoint (``jnp.min(cost + t[..., None, :], axis=-1)`` shape).
The one non-local ingredient is the sender NIC: the event simulator
serializes each drain's sends back-to-back at link bandwidth, so an edge's
cost depends on *when* its message reaches the head of the sender's queue.
We therefore alternate two vectorized passes until the joint fixpoint:

1. **NIC pass** — per server, sort all (round, message) forward events by
   their enqueue time and replay the FIFO NIC with a cumulative max-plus scan
   (``free_i = max(E_i, free_{i-1}) + occ_i``, computed with cumsum+cummax,
   no sequential loop).
2. **min-plus pass** — propagate send-completion times along overlay edges to
   get the next arrival estimates.

Both passes are pure array programs: they vmap over a batch of configs and
jit cleanly; the inner relaxation optionally dispatches to the Pallas
tropical-semiring kernel (``engine="pallas"``, bit-for-bit equal to the
jnp path — see README and ``repro.kernels.tropical``).  All K rounds are
relaxed jointly, which captures the pipelining
the protocol actually exhibits: round k+1 messages overtake stragglers of
round k and are postponed (G_U) or forwarded early (G_R) exactly like in the
event engine.

Semantics replicated from ``repro.sim.runner`` / ``repro.core.server``:

- G_U rounds (AllConcur+ failure-free, AllGather): source-rooted binomial
  trees; a round-(k+1) message reaching a server still in round k is
  *postponed* and forwarded only at the server's round transition.
- G_R rounds (AllConcur): flood with per-server forward-on-first-receipt;
  a round-(k+1) message reaching a server still in round k is forwarded
  immediately but *dropped* from the round state at the transition
  (``M_next`` is cleared), so it is re-forwarded and only *installed* when
  the next copy arrives in-round.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Tuple

import numpy as np

BIG = 1e12          # "not yet known" sentinel (finite: avoids inf-inf NaNs)
ENGINES = ("vec", "pallas")   # jnp gather relaxation | Pallas tropical kernel
_EPS = 1e-9         # fixpoint convergence tolerance (seconds): one ns is 4+
                    # orders below any reported latency; tighter values only
                    # chase float-rounding churn through the round pipeline


_CACHE_SET = False


def _jax():
    import jax
    import jax.numpy as jnp
    global _CACHE_SET
    if not _CACHE_SET:
        _CACHE_SET = True
        # persistent compilation cache: the large-n jit programs compile once
        # per machine instead of once per process (CI runs the bench twice)
        try:
            import os
            cache = os.environ.get(
                "VECSIM_JAX_CACHE",
                os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             ".jax_cache"))
            jax.config.update("jax_compilation_cache_dir",
                              os.path.abspath(cache))
        except Exception:
            pass
    return jax, jnp


@dataclass(frozen=True)
class RoundTimes:
    """Per-config round trajectory: ``completion[k, v]`` is the time server v
    completes round k+1 (k = 0..K-1); ``start[k, v]`` is its entry time."""
    completion: np.ndarray   # [..., K, n]
    start: np.ndarray        # [..., K, n]
    iterations: int


def _nic_scan(jnp, keys, occ, tx0):
    """Replay one server's FIFO NIC over its forward events.

    keys: lexsort key tuple, last key primary — and the primary key must be
    the enqueue time E.  Ties beyond the explicit keys fall back to flat
    item order (lexsort is stable), which encodes (round, source, event
    kind) by construction at every call site.  occ [m] is each event's NIC
    occupancy; tx0 is the NIC free time carried in from earlier (frozen)
    events.  Returns (start times [m], final free time): start is when each
    event's first send begins serializing — replicating the event heap's
    drain order.
    """
    import jax.lax as lax
    E = keys[-1]
    order = jnp.lexsort(keys)
    E_s, occ_s = E[order], occ[order]
    csum = jnp.cumsum(occ_s)
    prev = csum - occ_s
    free = csum + jnp.maximum(lax.cummax(E_s - prev, axis=0), tx0)
    start_sorted = free - occ_s
    return jnp.zeros_like(E).at[order].set(start_sorted), free[-1]


# ---------------------------------------------------------------------------
# G_U rounds: binomial-tree dissemination with postponement
# ---------------------------------------------------------------------------
#
# Postponement makes G_U rounds *sequential per server*: every round-k NIC
# event has E <= C_k[v] and every round-(k+1) event has E >= C_k[v], so the
# whole trajectory is a lax.scan over rounds carrying (round entry times,
# NIC free times), with a small per-round fixpoint inside (~tree depth
# iterations over [n, n] arrays instead of a joint K-round relaxation).

def _unreliable_round(jax, jnp, tstart, tx0, parent, send_off, occ, prop,
                      prop_from_parent, max_iters, relax_cost=None,
                      interpret=True):
    n = tstart.shape[0]
    eye = jnp.eye(n, dtype=bool)
    tsv = tstart[None, :]                      # round entry, per server column

    def passes(A):
        # processing-ready time: own message at round entry; received
        # messages clamp to round entry (postponed until the transition).
        # Sort keys (E, then arrival order, then flat index = source id):
        # postponed messages flush in arrival order before the own message.
        E = jnp.where(eye, tsv, jnp.maximum(A, tsv))
        Aeff = jnp.where(eye, tsv, A)          # tie key: real arrival order
        start, free_end = jax.vmap(
            lambda Ev, Av, ov, t0: _nic_scan(jnp, (Av, Ev), ov, t0),
            in_axes=(1, 1, 1, 0), out_axes=(1, 0))(E, Aeff, occ, tx0)
        if relax_cost is None:
            cand = (jnp.take_along_axis(start, parent, axis=1)
                    + send_off + prop_from_parent)
        else:
            # tropical kernel: per-source (1, n) x (n, n) min-plus — the one
            # finite entry per column is the parent edge, so the min-plus
            # contraction reproduces the tree gather bit-for-bit.  prop is
            # added after the min (single candidate: equivalent) to keep the
            # event sim's (start + send_off) + prop float association
            from ..kernels.tropical import tropical_matmul
            cand = tropical_matmul(start[:, None, :], relax_cost,
                                   interpret=interpret)[:, 0, :] \
                + prop_from_parent
        A_new = jnp.where(eye, tsv, cand)
        return A_new, E, free_end

    def cond(state):
        A, it, delta = state
        return (it < max_iters) & (delta > _EPS)

    def body(state):
        A, it, _ = state
        A_new, _E, _f = passes(A)
        delta = jnp.max(jnp.abs(jnp.clip(A_new, 0, BIG) - jnp.clip(A, 0, BIG)))
        return A_new, it + 1, delta

    A0 = jnp.where(eye, tsv, jnp.full((n, n), BIG, tstart.dtype))
    A, it, _ = jax.lax.while_loop(cond, body, (A0, jnp.int32(0),
                                               jnp.float64(BIG)))
    _A, E, free_end = passes(A)
    C = jnp.max(E, axis=0)                     # completion: last processing
    return C, free_end, it


def run_unreliable(parent, send_off, occ, prop, *, rounds: int,
                   max_iters: int = 0, engine: str = "vec") -> RoundTimes:
    """Relax K failure-free G_U rounds.  Batched: all array arguments may
    carry leading batch dimensions (vmapped out here).  ``engine="pallas"``
    lowers the relaxation onto the tropical min-plus kernel (bit-for-bit
    equal to the default jnp path; interpret-mode off-TPU)."""
    jax, jnp = _jax()
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    parent = np.asarray(parent)
    batch_shape = parent.shape[:-2]
    n = parent.shape[-1]
    K = rounds
    if not max_iters:
        max_iters = 2 * int(np.ceil(np.log2(max(n, 2)))) + 8

    fn = _compiled_unreliable(n, K, max_iters, engine)
    def flat(a):
        return np.asarray(a, np.float64).reshape(
            (-1,) + a.shape[len(batch_shape):])

    C, tstart, iters = fn(
        parent.reshape((-1, n, n)).astype(np.int32),
        flat(np.asarray(send_off)), flat(np.asarray(occ)),
        flat(np.asarray(prop)))
    C = np.asarray(C).reshape(batch_shape + (K, n))
    tstart = np.asarray(tstart).reshape(batch_shape + (K, n))
    return RoundTimes(completion=C, start=tstart, iterations=int(np.max(iters)))


@functools.lru_cache(maxsize=64)
def _compiled_unreliable(n: int, K: int, max_iters: int,
                         engine: str = "vec"):
    jax, jnp = _jax()
    from jax.experimental import enable_x64

    use_pallas = engine == "pallas"
    interpret = jax.default_backend() != "tpu"

    with enable_x64():
        def single(parent, send_off, occ, prop):
            prop_from_parent = prop[parent, jnp.arange(n)[None, :]]
            relax_cost = None
            if use_pallas:
                # dense per-source send-slot costs: the only finite entry in
                # column (s, :, v) is the parent edge of v in s's tree
                # (propagation is added after the contraction)
                s_idx = jnp.arange(n)[:, None]
                v_idx = jnp.arange(n)[None, :]
                relax_cost = jnp.full((n, n, n), jnp.inf, jnp.float64).at[
                    s_idx, parent, v_idx].set(send_off)

            def round_step(carry, _):
                tstart, tx0 = carry
                C, free_end, it = _unreliable_round(
                    jax, jnp, tstart, tx0, parent, send_off, occ, prop,
                    prop_from_parent, max_iters, relax_cost, interpret)
                return (C, free_end), (tstart, C, it)

            init = (jnp.zeros(n, jnp.float64), jnp.zeros(n, jnp.float64))
            _carry, (ts, C, its) = jax.lax.scan(round_step, init, None,
                                                length=K)
            return C, ts, jnp.max(its)

        fn = jax.jit(jax.vmap(single))

        def call(parent, send_off, occ, prop):
            with enable_x64():
                return fn(parent, send_off, occ, prop)
        return call


# ---------------------------------------------------------------------------
# G_R rounds: flood dissemination with early-forward + install
# ---------------------------------------------------------------------------

def _reliable_step(jax, jnp, A1, inst, tstart, pred, pred_cost, pred_mask,
                   occ, t0, pallas_tables=None, interpret=True):
    """One Jacobi sweep of the joint K-round G_R relaxation.

    ``pred[v, j]`` lists v's G_R predecessors (padded, masked by
    ``pred_mask``); ``pred_cost[v, j]`` is that edge's send-slot offset plus
    propagation, so candidates gather over d predecessors instead of a dense
    n^3 min-plus contraction.  With ``pallas_tables`` the same relaxation
    runs as a dense tropical-kernel min-plus over (cost2, has_pad) —
    bit-for-bit equal to the gather (see run_reliable).
    """
    K, n, _ = A1.shape
    k_idx = jnp.arange(K)
    eye = jnp.eye(n, dtype=bool)
    tsv = tstart[:, None, :]

    # event 1: first receipt (own message: round entry).  event 2: install
    # re-forward, only when the first copy came early (A1 < round entry).
    E1 = jnp.where(eye[None], tsv, A1)
    early = (~eye[None]) & (A1 < tsv)
    E2 = jnp.where(early, inst, BIG)

    occ_b = jnp.broadcast_to(occ[None, None, :], (K, n, n))
    rnd_b = jnp.broadcast_to(k_idx[:, None, None], (K, n, n)).astype(
        jnp.float64)

    def per_server(E1v, E2v, rv, ov):
        # sort keys (E, then round — a completing drain serializes the
        # finishing round's forwards before the next round's A-broadcast —
        # then flat order: round-k first receipts by source, then installs)
        E = jnp.concatenate([E1v.ravel(), E2v.ravel()])
        r = jnp.concatenate([rv.ravel(), rv.ravel()])
        o = jnp.where(E >= BIG, 0.0, jnp.concatenate([ov.ravel(), ov.ravel()]))
        st, _free = _nic_scan(jnp, (r, E), o, jnp.float64(0.0))
        return st[: K * n].reshape(K, n), st[K * n:].reshape(K, n)

    start1, start2 = jax.vmap(per_server, in_axes=(2, 2, 2, 2),
                              out_axes=2)(E1, E2, rnd_b, occ_b)

    if pallas_tables is None:
        # min-plus over G_R edges: gather both forward events of each
        # predecessor
        c1 = start1[:, :, pred] + pred_cost[None, None]   # [K, s, v, dmax]
        c2 = start2[:, :, pred] + pred_cost[None, None]
        c1 = jnp.where(pred_mask[None, None], c1, BIG)
        c2 = jnp.where(pred_mask[None, None], c2, BIG)
        cand = jnp.concatenate([c1, c2], axis=-1)         # [K, s, v, 2*dmax]
        A1_new = jnp.min(cand, axis=-1)
        in_round = jnp.where(cand >= tsv[..., None], cand, BIG)
        inst_new = jnp.min(in_round, axis=-1)
    else:
        # dense tropical min-plus: both forward events stack along the
        # contraction axis (same cost matrix), the install rule becomes the
        # kernel's threshold gate, and columns whose gather rows carried
        # BIG padding (in-degree < dmax) get the same min(., BIG) cap
        from ..kernels.tropical import tropical_matmul_threshold
        cost2, has_pad = pallas_tables                    # [2n, n], [n]
        S2 = jnp.concatenate([start1, start2], axis=-1)   # [K, s, 2n]
        thr = jnp.broadcast_to(tsv, (K, n, n))
        plain, gated = tropical_matmul_threshold(S2, cost2, thr, big=BIG,
                                                 interpret=interpret)
        pad = has_pad[None, None, :]
        A1_new = jnp.where(pad, jnp.minimum(plain, BIG), plain)
        inst_new = jnp.where(pad, jnp.minimum(gated, BIG), gated)
    A1_new = jnp.where(eye[None], tsv, A1_new)
    inst_new = jnp.where(eye[None], tsv, inst_new)

    C = jnp.max(inst_new, axis=1)
    tstart_new = jnp.concatenate([jnp.full((1, n), t0, A1.dtype), C[:-1]], 0)
    return A1_new, inst_new, tstart_new, C


def run_reliable(adj, edge_off, occ, prop, *, rounds: int,
                 max_iters: int = 0, engine: str = "vec") -> RoundTimes:
    """Relax K failure-free G_R (AllConcur) rounds to the joint fixpoint.

    G_R rounds interleave on the NIC (early forwards of round k+1 run while
    round k drains), so all K rounds relax jointly rather than sequentially.
    ``engine="pallas"`` lowers the flood relaxation onto the tropical
    min-plus kernel, bit-for-bit equal to the default jnp gather path.
    """
    jax, jnp = _jax()
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    adj = np.asarray(adj).astype(bool)
    batch_shape = adj.shape[:-2]
    n = adj.shape[-1]
    K = rounds
    if not max_iters:
        max_iters = 3 * K + 6 * (int(np.ceil(np.log2(max(n, 2)))) + 2) + 16

    adj_f = adj.reshape((-1, n, n))
    B = adj_f.shape[0]
    def flat(a):
        return np.asarray(a, np.float64).reshape(
            (-1,) + a.shape[len(batch_shape):])

    eoff_f, occ_f, prop_f = (flat(np.asarray(edge_off)), flat(np.asarray(occ)),
                             flat(np.asarray(prop)))

    # pad predecessor lists to the max in-degree across the batch
    dmax = int(adj_f.sum(axis=1).max())
    pred = np.zeros((B, n, dmax), dtype=np.int32)
    pred_cost = np.full((B, n, dmax), BIG, dtype=np.float64)
    pred_mask = np.zeros((B, n, dmax), dtype=bool)
    for b in range(B):
        for v in range(n):
            us = np.flatnonzero(adj_f[b, :, v])
            pred[b, v, :len(us)] = us
            pred_cost[b, v, :len(us)] = eoff_f[b, us, v] + prop_f[b, us, v]
            pred_mask[b, v, :len(us)] = True

    fn = _compiled_reliable(n, K, dmax, max_iters, True, engine)
    C, tstart, iters, resid = fn(pred, pred_cost, pred_mask, occ_f)
    C, resid = np.asarray(C), np.asarray(resid)
    # insurance: the warm-started solve must agree with the trustworthy cold
    # prefix and be fully resolved; otherwise redo the whole batch cold
    if (resid > 1e-9).any() or not np.isfinite(C).all() or (C > BIG / 2).any():
        fn = _compiled_reliable(n, K, dmax, 8 * max_iters, False, engine)
        C, tstart, iters, _ = fn(pred, pred_cost, pred_mask, occ_f)
        C = np.asarray(C)
    C = C.reshape(batch_shape + (K, n))
    tstart = np.asarray(tstart).reshape(batch_shape + (K, n))
    return RoundTimes(completion=C, start=tstart, iterations=int(np.max(iters)))


@functools.lru_cache(maxsize=64)
def _compiled_reliable(n: int, K: int, dmax: int, max_iters: int, warm: bool,
                       engine: str = "vec"):
    jax, jnp = _jax()
    from jax.experimental import enable_x64

    use_pallas = engine == "pallas"
    interpret = jax.default_backend() != "tpu"

    with enable_x64():
        def solve(Kc, pred, pred_cost, pred_mask, occ, ts0, iters_cap,
                  A0=None, inst0=None, pallas_tables=None):
            if A0 is None:
                A0 = jnp.full((Kc, n, n), BIG, jnp.float64)
            inst0 = A0 if inst0 is None else inst0
            t0 = jnp.zeros((), jnp.float64)

            def cond(state):
                A1, inst, ts, it, delta = state
                return (it < iters_cap) & (delta > _EPS)

            def body(state):
                A1, inst, ts, it, _ = state
                A1n, instn, tsn, _C = _reliable_step(
                    jax, jnp, A1, inst, ts, pred, pred_cost, pred_mask, occ,
                    t0, pallas_tables, interpret)
                delta = jnp.maximum(
                    jnp.max(jnp.abs(jnp.clip(A1n, 0, BIG) - jnp.clip(A1, 0, BIG))),
                    jnp.max(jnp.abs(jnp.clip(instn, 0, BIG) - jnp.clip(inst, 0, BIG))))
                return A1n, instn, tsn, it + 1, delta

            A1, inst, ts, it, _ = jax.lax.while_loop(
                cond, body, (A0, inst0, ts0, jnp.int32(0), jnp.float64(BIG)))
            A1, inst, _ts, C = _reliable_step(
                jax, jnp, A1, inst, ts, pred, pred_cost, pred_mask, occ, t0,
                pallas_tables, interpret)
            return C, ts, it, A1, inst

        def single(pred, pred_cost, pred_mask, occ):
            pallas_tables = None
            if use_pallas:
                # dense G_R edge costs (inf off-edge), stacked twice along
                # the contraction axis — once per forward event kind; gather
                # rows with BIG padding (in-degree < dmax) are flagged so
                # the dense min gets the identical BIG cap
                v_col = jnp.arange(n)[:, None]
                dense = jnp.full((n, n), jnp.inf, jnp.float64).at[
                    pred, v_col].min(
                        jnp.where(pred_mask, pred_cost, jnp.inf))
                pallas_tables = (jnp.concatenate([dense, dense], axis=0),
                                 ~jnp.all(pred_mask, axis=-1))
            # cold Jacobi resolves rounds strictly one-by-one (~settle
            # iterations each).  Warm-start: solve a short prefix cold, then
            # extrapolate round entries by the steady-state period so all K
            # rounds settle in parallel; the final while_loop still runs to
            # the exact joint fixpoint, and the caller cross-checks the
            # result against the cold prefix (resid) before trusting it.
            K1 = min(3, K)
            ts0 = jnp.concatenate(
                [jnp.zeros((1, n)), jnp.full((K1 - 1, n), BIG)], 0)
            if not warm or K1 == K:
                ts_cold = jnp.concatenate(
                    [jnp.zeros((1, n)), jnp.full((K - 1, n), BIG)], 0)
                C, ts, it, _A, _i = solve(K, pred, pred_cost, pred_mask, occ,
                                          ts_cold, jnp.int32(max_iters),
                                          pallas_tables=pallas_tables)
                return C, ts, it, jnp.float64(0.0)
            C1, _ts1, it1, A1_1, inst1 = solve(K1, pred, pred_cost, pred_mask,
                                               occ, ts0, jnp.int32(max_iters),
                                               pallas_tables=pallas_tables)
            # extrapolate entry times AND arrival matrices by the per-server
            # steady-state period so late rounds start near their fixpoint
            period = C1[-1] - C1[-2]                       # per-server [n]
            k_off = jnp.arange(1, K - K1 + 1, dtype=jnp.float64)[:, None, None]
            ts_warm = jnp.concatenate(
                [jnp.zeros((1, n)), C1[:-1],
                 C1[-1][None]
                 + jnp.arange(K - K1, dtype=jnp.float64)[:, None]
                 * period[None]], 0)
            shift = k_off * period[None, None, :]          # [K-K1, 1, n]
            A_warm = jnp.concatenate([A1_1, A1_1[-1][None] + shift], 0)
            inst_warm = jnp.concatenate([inst1, inst1[-1][None] + shift], 0)
            C, ts, it2, _A, _i = solve(K, pred, pred_cost, pred_mask, occ,
                                       ts_warm, jnp.int32(max_iters),
                                       A0=A_warm, inst0=inst_warm,
                                       pallas_tables=pallas_tables)
            resid = jnp.max(jnp.abs(C[:K1] - C1))
            return C, ts, it1 + it2, resid

        fn = jax.jit(jax.vmap(single))

        def call(pred, pred_cost, pred_mask, occ):
            with enable_x64():
                return fn(pred, pred_cost, pred_mask, occ)
        return call


# ---------------------------------------------------------------------------
# metrics: replicate repro.sim.runner.Metrics summaries from round times
# ---------------------------------------------------------------------------

def summarize(times: RoundTimes, *, mode: str, n: int, batch: int,
              window: Tuple[int, int] = (10, 110)) -> dict:
    """Per-config summary matching the event engine's ``Metrics`` semantics.

    Deliver events: AllGather / AllConcur deliver round k at its completion;
    AllConcur+ (DUAL) delivers round k-1 when round k completes (and round 1,
    the first ``|>`` round, delivers nothing).  Latency is A-broadcast (round
    entry) to own-message A-delivery, as in ``Metrics.on_deliver_msg``.
    """
    C, ts = times.completion, times.start        # [..., K, n]
    K = C.shape[-2]
    lo_mult, hi_mult = window

    if mode == "allconcur+":
        deliver = C[..., 1:, :]                  # round k-1 delivered at C_k
        lat = C[..., 1:, :] - ts[..., :-1, :]    # abcast at entry of k-1
    else:
        deliver = C
        lat = C - ts
    median_latency = np.median(lat, axis=(-2, -1))

    # window(): per server, accumulate n msgs per deliver event; t1/t2 are the
    # max over servers of the first event reaching lo/hi * n messages.
    nev = deliver.shape[-2]
    lo_ev, hi_ev = lo_mult, hi_mult              # acc after j events = j * n
    t1 = np.max(deliver[..., lo_ev - 1, :], axis=-1) if lo_ev <= nev \
        else np.zeros(C.shape[:-2])
    if hi_ev <= nev:
        t2 = np.max(deliver[..., hi_ev - 1, :], axis=-1)
    else:
        t2 = np.max(deliver[..., -1, :], axis=-1)    # fallback: last event
    span = t2 - t1
    with np.errstate(invalid="ignore", divide="ignore"):
        in_win = ((deliver > t1[..., None, None])
                  & (deliver <= t2[..., None, None])).sum(axis=-2)
        msgs = in_win * n * batch
        thr = np.where(span > 0, msgs.mean(axis=-1) / np.where(span > 0, span, 1.0),
                       np.nan)
    return {
        "median_latency": median_latency,
        "throughput": thr,
        "t_window": (t1, t2),
        "round_period": np.median(np.diff(np.max(C, axis=-1), axis=-1), axis=-1),
        "completion": C,
    }
