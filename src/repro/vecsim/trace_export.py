"""Synthetic traces from vecsim configurations — critical paths at sweep
scale.

The jitted engine (:mod:`repro.vecsim.engine`) reduces each deployment to
per-round per-server completion timelines; that is enough for
latency/throughput sweeps but too coarse for causal analysis — a critical
path needs every hop.  This module closes the gap with a *lean replay*: a
table-driven, failure-free re-execution of the protocol's dissemination
(binomial G_U trees for BCAST rounds, the G_R flood for RBCAST rounds)
using **bit-identical arithmetic to the discrete-event simulator** — the
same ``t = max(now, tx_free); t += serialization; arrive = t +
propagation`` float operations in the same order, the same heap tie-break
— so the synthetic trace it emits is event-for-event comparable with a
real :mod:`repro.sim` trace and the critical-path decompositions
(:mod:`repro.obs.critpath`) match *exactly*, not within tolerance.

One replay costs milliseconds of Python per configuration, so critical
paths are computable across the full Monte-Carlo grids the sweep engine
jits — thousands of (n, network, batch, mode) points — while the engine's
lumped closed-form (``(j+1) * ser`` cumulative sums instead of repeated
``t += ser``) keeps owning the thousands-of-seeds robustness numbers;
:func:`engine_consistency` ties the two together numerically.

Scope: failure-free, fixed-membership runs of the three modes
(``allconcur+``, ``allconcur``, ``allgather``) — exactly the regime the
engine's recurrence models.  Crash and eon-flip causality comes from the
event simulator's real traces.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.digraph import gs_digraph, resilience_degree
from ..core.overlay import make_overlay
from ..sim.network import make_network
from .topology import message_bytes

_MODES = ("allconcur+", "allconcur", "allgather")


class _USrv:
    """Failure-free unreliable-round server (DUAL / AllGather): Algorithm 2
    + the T_UU completion path of Algorithm 5, dissemination on G_U."""

    __slots__ = ("sid", "round", "M", "M_next", "M_prev_round", "outbox",
                 "ndelivered")

    def __init__(self, sid: int):
        self.sid = sid
        self.round = 1
        self.M: set = set()
        self.M_next: Dict[int, int] = {}    # src -> round (arrival order)
        self.M_prev_round: Optional[int] = None
        self.outbox: List[Tuple[int, Tuple[int, int]]] = []
        self.ndelivered = 0


class _RSrv:
    """Failure-free reliable-round server (AllConcur): Algorithm 3 + the
    T_RR completion path, dissemination by G_R flood.  Failure-free,
    ``epoch == round`` throughout."""

    __slots__ = ("sid", "round", "M", "M_next", "outbox", "ndelivered")

    def __init__(self, sid: int):
        self.sid = sid
        self.round = 1
        self.M: set = set()
        self.M_next: Dict[int, int] = {}
        self.outbox: List[Tuple[int, Tuple[int, int]]] = []
        self.ndelivered = 0


class _Replay:
    def __init__(self, mode: str, n: int, *, batch: int, network: str,
                 d: Optional[int], overlay: str):
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
        self.mode = mode
        self.n = n
        self.members = list(range(n))
        self.ov = make_overlay(overlay, self.members)
        self.g_r = (gs_digraph(self.members,
                               d if d is not None else resilience_degree(n))
                    if mode == "allconcur" else None)
        self.net = make_network(network, n)
        self.size = message_bytes(mode, batch)
        self.mkind = "RBCAST" if mode == "allconcur" else "BCAST"
        self.g = "GR" if mode == "allconcur" else "GU"
        self.now = 0.0
        self.tx_free = {sid: 0.0 for sid in self.members}
        self._heap: List[Tuple[float, int, int, int, Tuple[int, int]]] = []
        self._seq = itertools.count()
        self.events: List[Tuple[float, str, int, Dict[str, Any]]] = []
        cls = _RSrv if mode == "allconcur" else _USrv
        self.srvs = {sid: cls(sid) for sid in self.members}

    # -- event emission (same field vocabulary as the live recorders) ------
    def _desc(self, msrc: int, rnd: int) -> Dict[str, Any]:
        epoch = rnd if self.mode == "allconcur" else 1
        return {"m": "msg", "mkind": self.mkind, "msrc": msrc,
                "epoch": epoch, "round": rnd, "eon": 0, "g": self.g}

    def _emit(self, kind: str, sid: int, **fields: Any) -> None:
        self.events.append((self.now, kind, sid, fields))

    # -- NIC: bit-identical to Simulation.drain ----------------------------
    def _drain(self, sid: int) -> None:
        srv = self.srvs[sid]
        out, srv.outbox = srv.outbox, []
        t = max(self.now, self.tx_free[sid])
        for dst, (msrc, rnd) in out:
            txs = t
            t += self.net.serialization(self.size, sid, dst)
            arrive = t + self.net.propagation(sid, dst)
            heapq.heappush(self._heap,
                           (arrive, next(self._seq), dst, sid, (msrc, rnd)))
            self.events.append((self.now, "send", sid,
                                dict(self._desc(msrc, rnd), dst=dst,
                                     bytes=self.size, txs=txs, txe=t)))
        self.tx_free[sid] = t

    # -- protocol (failure-free subset, hop-for-hop) -----------------------
    def _abcast(self, srv) -> None:
        if srv.sid in srv.M:
            return
        rnd = srv.round
        epoch = rnd if self.mode == "allconcur" else 1
        self._emit("abcast", srv.sid, mkind=self.mkind, epoch=epoch,
                   round=rnd, eon=0)
        self._forward(srv, srv.sid, rnd)

    def _forward(self, srv, msrc: int, rnd: int) -> None:
        if msrc in srv.M:
            return
        hops = (self.g_r.successors(srv.sid) if self.g_r is not None
                else self.ov.next_hops(msrc, srv.sid))
        for q in hops:
            srv.outbox.append((q, (msrc, rnd)))
        srv.M.add(msrc)

    def _deliver(self, srv, rnd: int) -> None:
        epoch = rnd if self.mode == "allconcur" else 1
        rtype = "RELIABLE" if self.mode == "allconcur" else "UNRELIABLE"
        self._emit("deliver", srv.sid, epoch=epoch, round=rnd, rtype=rtype,
                   eon=0, nmsgs=self.n, srcs=list(self.members))
        srv.ndelivered += 1

    def _on_message(self, sid: int, msrc: int, rnd: int) -> None:
        srv = self.srvs[sid]
        if rnd < srv.round:
            return                       # late duplicate copy — drop
        if rnd > srv.round:
            if rnd != srv.round + 1:
                return                   # impossible among non-faulty
            if self.mode == "allconcur":
                # premature RBCAST (#6): forward now, install at T_RR
                if msrc in srv.M_next:
                    return               # duplicate via another G_R path
                for q in self.g_r.successors(sid):
                    srv.outbox.append((q, (msrc, rnd)))
            srv.M_next.setdefault(msrc, rnd)
            return
        self._forward(srv, msrc, rnd)
        self._abcast(srv)                # no-op (own message already sent)
        self._try_complete(srv)

    def _try_complete(self, srv) -> None:
        while len(srv.M) == self.n:
            if self.mode == "allconcur+":
                # completing [e,r] A-delivers [e,r-1] (T_UU)
                if srv.M_prev_round is not None:
                    self._deliver(srv, srv.M_prev_round)
                srv.M_prev_round = srv.round
            else:
                self._deliver(srv, srv.round)
            srv.round += 1
            postponed = list(srv.M_next)
            srv.M = set()
            srv.M_next = {}
            if self.mode == "allconcur":
                # T_RR installs premature messages without re-forwarding
                srv.M.update(postponed)
            else:
                for msrc in postponed:
                    self._forward(srv, msrc, srv.round)
            self._abcast(srv)

    # -- event loop: same (t, seq) heap order as Simulation.run ------------
    def run(self, rounds: int) -> None:
        for sid in self.members:
            srv = self.srvs[sid]
            self._abcast(srv)
            self._drain(sid)
        while self._heap:
            if min(s.ndelivered for s in self.srvs.values()) >= rounds:
                return
            t, _seq, dst, src, (msrc, rnd) = heapq.heappop(self._heap)
            self.now = t
            self._emit("recv", dst, src=src, **self._desc(msrc, rnd))
            self._on_message(dst, msrc, rnd)
            self._drain(dst)


def synthetic_trace(mode: str, n: int, *, rounds: int, batch: int = 4,
                    network: str = "sdc", d: Optional[int] = None,
                    overlay: str = "binomial"
                    ) -> List[Tuple[float, str, int, Dict[str, Any]]]:
    """Replay a failure-free configuration and return its synthetic trace
    (recorder-tuple form), directly consumable by
    :func:`repro.obs.critpath.critical_paths`,
    :func:`repro.obs.diff.diff_traces` and the work accountant.  Runs until
    every server has A-delivered ``rounds`` rounds."""
    rep = _Replay(mode, n, batch=batch, network=network, d=d,
                  overlay=overlay)
    rep.run(rounds)
    return rep.events


def deliver_times(events, n: int) -> Dict[int, np.ndarray]:
    """Per-round delivery timeline from a trace: round -> float64[n] of
    per-server A-delivery times (NaN where a server never delivered it)."""
    out: Dict[int, np.ndarray] = {}
    for t, kind, sid, f in events:
        if kind != "deliver":
            continue
        rnd = f.get("round")
        row = out.get(rnd)
        if row is None:
            row = out[rnd] = np.full(n, np.nan)
        if np.isnan(row[sid]):
            row[sid] = t
    return out


def critical_paths_for_config(mode: str, n: int, *, rounds: int,
                              batch: int = 4, network: str = "sdc",
                              d: Optional[int] = None):
    """Sweep-scale entry point: synthesize the trace for one configuration
    and decompose every delivery's critical path."""
    from ..obs.critpath import critical_paths
    return critical_paths(synthetic_trace(
        mode, n, rounds=rounds, batch=batch, network=network, d=d))


def engine_consistency(mode: str, n: int, *, rounds: int, batch: int = 4,
                       network: str = "sdc", d: Optional[int] = None,
                       engine: str = "vec") -> Tuple[float, float]:
    """(replay median latency, engine median latency) for one config — the
    numerical tie between the hop-level replay and the jitted lumped
    recurrence.  They agree to ~1e-3 relative (the engine accumulates NIC
    occupancy as ``k * ser`` products, the replay as the event simulator's
    repeated ``t += ser``), the same band the engine is validated to
    against the event simulator."""
    from .engine import run_reliable, run_unreliable, summarize
    from .topology import reliable_tables, unreliable_tables

    rep = _Replay(mode, n, batch=batch, network=network, d=d,
                  overlay="binomial")
    rep.run(rounds)
    lats = []
    abcast_t: Dict[Tuple[int, int], float] = {}
    for t, kind, sid, f in rep.events:
        if kind == "abcast":
            abcast_t.setdefault((sid, f["round"]), t)
        elif kind == "deliver":
            t0 = abcast_t.get((sid, f["round"]))
            if t0 is not None:
                lats.append(t - t0)
    lats.sort()
    replay_median = lats[len(lats) // 2] if lats else float("nan")

    if mode == "allconcur":
        tb = reliable_tables(n, d=d, network=network, batch=batch)
        times = run_reliable(tb.adj, tb.edge_off, tb.occ, tb.prop,
                             rounds=rounds + 2, engine=engine)
    else:
        tb = unreliable_tables(n, network=network, batch=batch, mode=mode)
        times = run_unreliable(tb.parent, tb.send_off, tb.occ, tb.prop,
                               rounds=rounds + 2, engine=engine)
    summ = summarize(times, mode=mode, n=n, batch=batch)
    return replay_median, float(summ["median_latency"])
