"""Lower protocol topology + network model into dense cost arrays.

The event simulator (``repro.sim.runner``) walks ``Digraph`` /
``UnreliableOverlay`` / ``NetworkModel`` objects one message at a time.  The
vectorized engine instead consumes a handful of dense per-config arrays, all
produced here from the *same* objects so there is exactly one source of truth
for routing, send order and message cost:

- ``prop[u, v]``      — path propagation latency (``NetworkModel.propagation``)
- ``send_off[s, v]``  — cumulative NIC serialization at ``parent[s, v]`` up to
                        and including the send of message ``s`` towards ``v``
                        (the event sim serializes a drain's sends in outbox
                        order; the offset encodes that order statically)
- ``occ[s, u]``       — total NIC occupancy of forwarding message ``s`` at
                        ``u`` (sum of per-hop serialization times)

Message sizes go through :func:`repro.sim.runner.wire_size` on synthetic
``Message`` instances — which is now the *encoded frame length* from
:mod:`repro.wire` — so header/batch byte accounting can never drift from
the event engine or from the bytes an actual codec round-trip produces.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.digraph import Digraph, gs_digraph, resilience_degree
from ..core.messages import Message, MsgKind
from ..core.overlay import make_overlay
from ..sim.network import make_network
from ..sim.runner import wire_size

MODES = ("allconcur+", "allconcur", "allgather")


@dataclass(frozen=True)
class UnreliableTables:
    """Binomial-tree (G_U) dissemination lowered to dense arrays.

    Every message travels a tree rooted at its source: each server ``v != s``
    has exactly one ``parent[s, v]`` that relays message ``s`` to it.
    """
    n: int
    parent: np.ndarray     # [n, n] int32; parent[s, s] = s
    send_off: np.ndarray   # [n, n] float64; cumulative ser at parent for s->v
    occ: np.ndarray        # [n, n] float64; occ[s, u] = total ser of s at u
    prop: np.ndarray       # [n, n] float64
    ser: float             # per-message serialization time (constant model)


@dataclass(frozen=True)
class ReliableTables:
    """G_R flood dissemination lowered to dense arrays.

    Every server forwards each message to *all* its G_R successors on first
    receipt; ``edge_off[u, v]`` is the cumulative serialization at ``u`` up to
    and including the send towards successor ``v`` (BIG for non-edges).
    """
    n: int
    d: int
    adj: np.ndarray        # [n, n] bool; adj[u, v] = G_R edge u -> v
    edge_off: np.ndarray   # [n, n] float64; cumulative ser at u for u -> v
    occ: np.ndarray        # [n] float64; total ser of one flood-forward at u
    prop: np.ndarray       # [n, n] float64
    ser: float


def message_bytes(mode: str, batch: int) -> int:
    """Wire bytes of one A-broadcast message, via the event sim's wire_size
    (= the encoded frame length, ``len(repro.wire.encode(probe))``).

    AllConcur+ failure-free rounds and AllGather rounds carry BCAST messages;
    AllConcur (RELIABLE_ONLY) rounds carry RBCAST messages.  With the real
    codec the fault-tolerant fields are varints carried by both kinds, so
    the old modeled 32-byte RBCAST surcharge collapses to nothing — the
    honest failure-free header cost the paper's §V argument relies on.
    """
    kind = MsgKind.RBCAST if mode == "allconcur" else MsgKind.BCAST
    probe = Message(kind, 0, 1, 1, payload={"batch": batch})
    return wire_size(probe, n=0)


def smr_message_bytes(mode: str, batch: int, *, value_size: int = 16) -> int:
    """Wire bytes of one failure-free SMR round message carrying ``batch``
    put requests, via the same probe-encode path as :func:`message_bytes`.

    The probe mirrors ``SMRService.payload_for`` exactly: a ``reqs`` tuple of
    ``(client_id, seq, op)`` with padded values, so the frame length matches
    the event simulator byte-for-byte *within the small-varint band* — all of
    client_id, seq, payload round and key must encode to one zigzag-varint
    byte (value <= 63) and ``value_size >= 6`` must absorb the ``"v%d.%d"``
    prefix.  The exactness tests stay inside this band; sweep-scale runs use
    the probe as the representative constant frame size.
    """
    kind = MsgKind.RBCAST if mode == "allconcur" else MsgKind.BCAST
    reqs = []
    for c in range(batch):
        value = "v%d.%d" % (c % 64, 0)
        value += "x" * max(value_size - len(value), 0)
        reqs.append((c % 64, 0, {"op": "put", "key": 0, "value": value}))
    payload = {"kind": "smr", "src": 0, "round": 1, "batch": len(reqs),
               "reqs": tuple(reqs)}
    return wire_size(Message(kind, 0, 1, 1, payload=payload), n=0)


def prop_matrix(network: str, n: int) -> np.ndarray:
    net = make_network(network, n)
    prop = np.zeros((n, n), dtype=np.float64)
    for u in range(n):
        for v in range(n):
            if u != v:
                prop[u, v] = net.propagation(u, v)
    return prop


def _ser_time(network: str, n: int, nbytes: int) -> float:
    """Per-message NIC serialization time.  All current network models charge
    a sender-side constant (bytes/bandwidth + software overhead); assert that
    so the dense tables stay valid if a model ever becomes pair-dependent."""
    net = make_network(network, n)
    times = {net.serialization(nbytes, u, v)
             for u in range(min(n, 4)) for v in range(n) if u != v}
    if len(times) != 1:
        raise NotImplementedError(
            "vecsim assumes sender-constant serialization; got per-pair "
            f"times {sorted(times)[:4]}... for network={network!r}")
    return times.pop()


@functools.lru_cache(maxsize=512)
def unreliable_tables(n: int, *, network: str = "sdc", batch: int = 4,
                      overlay: str = "binomial", mode: str = "allconcur+",
                      nbytes: Optional[int] = None) -> UnreliableTables:
    """Sweep grids repeat identical (n, network, batch) points across seeds
    and algorithms, so tables are cached; treat the arrays as read-only.

    ``nbytes`` overrides the probe message size (e.g.
    :func:`smr_message_bytes` for SMR-sized rounds); by default the plain
    A-broadcast probe of :func:`message_bytes` is used.
    """
    ov = make_overlay(overlay, list(range(n)))
    ser = _ser_time(network, n,
                    message_bytes(mode, batch) if nbytes is None else nbytes)
    parent = np.full((n, n), -1, dtype=np.int32)
    send_off = np.zeros((n, n), dtype=np.float64)
    occ = np.zeros((n, n), dtype=np.float64)
    for s in range(n):
        parent[s, s] = s
        for u in range(n):
            hops = ov.next_hops(s, u)
            occ[s, u] = len(hops) * ser
            for j, w in enumerate(hops):
                parent[s, w] = u
                send_off[s, w] = (j + 1) * ser
    if (parent < 0).any():
        raise ValueError(f"overlay {overlay!r} does not reach every server")
    return UnreliableTables(n=n, parent=parent, send_off=send_off, occ=occ,
                            prop=prop_matrix(network, n), ser=ser)


def reliable_tables(n: int, *, d: Optional[int] = None, network: str = "sdc",
                    batch: int = 4, g_r: Optional[Digraph] = None,
                    mode: str = "allconcur",
                    nbytes: Optional[int] = None) -> ReliableTables:
    if g_r is None:
        return _reliable_tables_cached(n, d=d, network=network, batch=batch,
                                       mode=mode, nbytes=nbytes)
    return _reliable_tables(n, d=d, network=network, batch=batch, g_r=g_r,
                            mode=mode, nbytes=nbytes)


@functools.lru_cache(maxsize=512)
def _reliable_tables_cached(n: int, *, d: Optional[int], network: str,
                            batch: int, mode: str,
                            nbytes: Optional[int]) -> ReliableTables:
    return _reliable_tables(n, d=d, network=network, batch=batch, g_r=None,
                            mode=mode, nbytes=nbytes)


def _reliable_tables(n: int, *, d: Optional[int], network: str, batch: int,
                     g_r: Optional[Digraph], mode: str,
                     nbytes: Optional[int] = None) -> ReliableTables:
    dd = d if d is not None else resilience_degree(n)
    g = g_r if g_r is not None else gs_digraph(list(range(n)), dd)
    ser = _ser_time(network, n,
                    message_bytes(mode, batch) if nbytes is None else nbytes)
    adj = np.zeros((n, n), dtype=bool)
    edge_off = np.zeros((n, n), dtype=np.float64)
    occ = np.zeros(n, dtype=np.float64)
    for u in range(n):
        succ = g.successors(u)
        occ[u] = len(succ) * ser
        for j, v in enumerate(succ):
            adj[u, v] = True
            edge_off[u, v] = (j + 1) * ser
    return ReliableTables(n=n, d=dd, adj=adj, edge_off=edge_off, occ=occ,
                          prop=prop_matrix(network, n), ser=ser)
